"""Continuous-batching scheduler + bucketed-compile regressions.

Covers the request lifecycle (admission / refill order, per-request
EOS & max-token termination), slot-indexed cache claim/reset, inactive-
slot trace masking, the one-bucket-one-compile guarantee, padded-prefill
exactness, cache-dtype propagation, the cached router_trace jit, and the
byte-for-byte offload-report equivalence between a scheduled run and the
same requests as one fixed batch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.models import forward, init_params
from repro.models.transformer import (ExecContext, cache_claim_slot,
                                      cache_reset_slot, init_caches,
                                      unstack_params)
from repro.serve import Request, Scheduler, ServeEngine, bucket_len, \
    router_trace


def moe_cfg(layers=2):
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=layers, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=3)))


def compress(cfg, params):
    """(cfg', qparams, stacks_by_layer) with every MoE layer compressed."""
    up = unstack_params(params, cfg)
    segs, stacks_by_layer = [], []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                         cfg.moe.quant)
        stacks_by_layer.append(stacks)
        mp["stacks"] = stacks
        for k in ("w1", "w2", "w3"):
            mp.pop(k)
        p["moe"] = mp
        segs.append((p,))
    q = dict(up)
    q["segments"] = tuple(segs)
    return dataclasses.replace(cfg, force_unroll_plan=True), q, \
        stacks_by_layer


# ---------------------------------------------------------------------------
# pure scheduler bookkeeping
# ---------------------------------------------------------------------------

def _req(uid, plen=4, max_new=3, eos=None, arrival=0.0):
    return Request(uid=uid, tokens=np.zeros(plen, np.int32),
                   max_new=max_new, eos_id=eos, arrival_s=arrival)


def test_scheduler_admission_and_refill_order():
    s = Scheduler(2)
    for i in range(5):
        s.submit(_req(i, max_new=2))
    assert [(i, r.uid) for i, r in s.admit(0.0)] == [(0, 0), (1, 1)]
    assert s.admit(0.0) == []                      # no free slot
    # chunk of 3 steps: max_new=2 retires both mid-chunk; step 3 rejected
    toks = np.arange(6).reshape(2, 3)
    lps = np.zeros((2, 3), np.float32)
    accepted = s.record_chunk(toks, lps, None, now=1.0)
    np.testing.assert_array_equal(accepted,
                                  [[True, True], [True, True],
                                   [False, False]])
    assert [r.uid for r in s.finished] == [0, 1]
    assert all(r.finish_reason == "length" and r.gen_tokens == 2
               for r in s.finished)
    # freed slots refill FIFO: 2 and 3, then 4 after another retirement
    assert [(i, r.uid) for i, r in s.admit(1.0)] == [(0, 2), (1, 3)]
    s.record_chunk(toks, lps, None, now=2.0)
    assert [(i, r.uid) for i, r in s.admit(2.0)] == [(0, 4)]
    assert s.has_work()
    s.record_chunk(toks[:1], lps[:1], None, now=3.0)
    assert not s.has_work()
    assert [r.uid for r in s.finished] == [0, 1, 2, 3, 4]


def test_scheduler_zero_token_budget():
    s = Scheduler(1)
    s.submit(_req(0, max_new=0))
    s.admit(0.0)
    acc = s.record_chunk(np.zeros((1, 2), np.int64),
                         np.zeros((1, 2), np.float32), None, 1.0)
    assert not acc.any()
    assert s.finished[0].gen_tokens == 0
    assert s.finished[0].finish_reason == "length"


def test_scheduler_eos_and_arrival_gating():
    s = Scheduler(1)
    s.submit(_req(0, max_new=8, eos=7))
    s.submit(_req(1, max_new=8, arrival=100.0))
    s.admit(0.0)
    toks = np.array([[3, 7, 5]])                   # EOS at step 1
    accepted = s.record_chunk(toks, np.zeros((1, 3), np.float32), None, 1.0)
    np.testing.assert_array_equal(accepted, [[True], [True], [False]])
    res = s.finished[0]
    assert res.finish_reason == "eos"
    assert res.tokens.tolist() == [3, 7]           # EOS included, then stop
    assert s.admit(1.0) == []                      # uid 1 hasn't arrived
    assert s.next_arrival() == 100.0
    assert [(i, r.uid) for i, r in s.admit(100.5)] == [(0, 1)]


# ---------------------------------------------------------------------------
# slot-indexed cache ops
# ---------------------------------------------------------------------------

def test_cache_claim_and_reset_slot():
    cfg = moe_cfg(layers=2)        # one scanned segment (repeat=2)
    caches = init_caches(cfg, 3, max_len=32, dtype=jnp.float32)
    req = jax.tree.map(jnp.ones_like, init_caches(cfg, 1, max_len=32,
                                                  dtype=jnp.float32))
    claimed = cache_claim_slot(cfg, caches, req, 1)
    layer = claimed["segments"][0][0]              # leaves (repeat, B, ...)
    assert float(layer["k"][:, 1].min()) == 1.0    # claimed row written
    assert float(layer["k"][:, 0].max()) == 0.0    # neighbours untouched
    assert int(layer["pos"][0, 1, 0]) == 1
    assert int(layer["pos"][0, 0, 0]) == -1
    assert claimed["pos"].tolist() == [0, 1, 0]
    reset = cache_reset_slot(cfg, claimed, 1)
    layer = reset["segments"][0][0]
    assert float(layer["k"][:, 1].max()) == 0.0
    assert int(layer["pos"][0, 1, 0]) == -1        # back to empty sentinel
    assert reset["pos"].tolist() == [0, 0, 0]


# ---------------------------------------------------------------------------
# engine: buckets, dtype, padded prefill
# ---------------------------------------------------------------------------

def test_bucket_len():
    assert bucket_len(1) == 32 and bucket_len(33) == 64
    assert bucket_len(64) == 64 and bucket_len(65) == 128
    assert bucket_len(5, minimum=16) == 16


def test_same_bucket_single_compile():
    """Two prompt lengths (and a scheduled ragged run) in one bucket must
    compile each jitted entry point exactly once."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    eng.generate(np.zeros((1, 5), np.int32), max_new=4)
    first = eng.num_compiles
    eng.generate(np.zeros((1, 7), np.int32), max_new=4)
    eng.generate(np.zeros((1, 9), np.int32), max_new=4)
    assert first == {"prefill": 1, "decode": 1}
    assert eng.num_compiles == first

    eng2 = ServeEngine(cfg, params)
    rng = np.random.default_rng(0)
    stats = eng2.generate_many(
        [rng.integers(0, 128, (int(l),), dtype=np.int32)
         for l in (4, 7, 9, 12, 5)], max_new=5, num_slots=2, chunk=4)
    assert [r.gen_tokens for r in stats.results] == [5] * 5
    assert eng2.num_compiles == {"prefill": 1, "decode": 1}


def test_cache_dtype_follows_params():
    cfg = moe_cfg()
    p32 = init_params(jax.random.key(0), cfg, jnp.float32)
    assert ServeEngine(cfg, p32).cache_dtype == jnp.float32
    pbf = init_params(jax.random.key(0), cfg, jnp.bfloat16)
    eng = ServeEngine(cfg, pbf)
    assert eng.cache_dtype == jnp.bfloat16
    assert ServeEngine(cfg, pbf,
                       cache_dtype=jnp.float32).cache_dtype == jnp.float32
    res = eng.generate(np.zeros((1, 4), np.int32), max_new=3)
    assert res.tokens.shape == (1, 3)


def test_padded_prefill_matches_unpadded_oracle():
    """Right-padded bucketed prefill + pos masking must decode exactly
    like an unpadded full-forward greedy loop."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(1), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 128, (1, 6), dtype=np.int32)  # pads to 16
    ctx = ExecContext(mode="train", exact_capacity=True)
    seq, oracle = prompt.copy(), []
    for _ in range(5):
        out = forward(params, jnp.asarray(seq), cfg, ctx)
        nxt = int(jnp.argmax(out.logits[0, -1]))
        oracle.append(nxt)
        seq = np.concatenate([seq, [[nxt]]], axis=1)
    got = ServeEngine(cfg, params).generate(prompt, max_new=5)
    assert got.tokens[0].tolist() == oracle


# ---------------------------------------------------------------------------
# engine: scheduled serving
# ---------------------------------------------------------------------------

def test_serve_per_request_termination():
    cfg = moe_cfg()
    params = init_params(jax.random.key(2), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 128, (6,), dtype=np.int32) for _ in range(3)]
    # greedy reference run (no EOS): learn what request 0 will emit
    ref = eng.generate_many(prompts, max_new=6, num_slots=2, chunk=3)
    eos = int(ref.results[0].tokens[2])
    reqs = [Request(uid=0, tokens=prompts[0], max_new=6, eos_id=eos),
            Request(uid=1, tokens=prompts[1], max_new=4),
            Request(uid=2, tokens=prompts[2], max_new=6)]
    stats = eng.serve(reqs, num_slots=2, chunk=3)
    r0, r1, r2 = stats.results
    assert r0.finish_reason == "eos" and r0.gen_tokens == 3
    assert int(r0.tokens[-1]) == eos
    assert r0.tokens.tolist() == ref.results[0].tokens[:3].tolist()
    assert r1.finish_reason == "length" and r1.gen_tokens == 4
    assert r2.finish_reason == "length" and r2.gen_tokens == 6
    assert stats.generated_tokens == 3 + 4 + 6
    # per-request traces follow the (gen, layers, k) convention
    assert r0.trace.shape == (3, 2, 2)
    assert r2.trace.shape == (6, 2, 2)


def test_serve_results_in_submission_order():
    """Results come back in submission order even when arrival times are
    not monotone with it (serving order follows arrivals)."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(2), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    reqs = [Request(uid=10, tokens=np.zeros(9, np.int32), max_new=2,
                    arrival_s=0.3),
            Request(uid=11, tokens=np.zeros(4, np.int32), max_new=3,
                    arrival_s=0.0)]
    stats = eng.serve(reqs, num_slots=1, chunk=2)
    assert [r.uid for r in stats.results] == [10, 11]
    assert [r.prompt_len for r in stats.results] == [9, 4]
    assert [r.gen_tokens for r in stats.results] == [2, 3]


def test_serve_inactive_slot_trace_masking():
    """Empty / retired slots must appear as -1 in the aggregate trace and
    be excluded from the accepted-token count."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(2), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    reqs = [Request(uid=0, tokens=np.zeros(4, np.int32), max_new=5),
            Request(uid=1, tokens=np.zeros(6, np.int32), max_new=2)]
    stats = eng.serve(reqs, num_slots=3, chunk=4)      # slot 2 never used
    tr = stats.router_trace                 # (steps, layers, slots, k)
    assert tr.shape[2] == 3
    assert (tr[:, :, 2, :] == -1).all()                # never-active slot
    active0 = (tr[:, 0, 0, 0] >= 0).sum()
    active1 = (tr[:, 0, 1, 0] >= 0).sum()
    assert {int(active0), int(active1)} == {5, 2}      # masked after retire
    assert stats.generated_tokens == 7
    valid = tr[tr >= 0]
    assert valid.size == 7 * cfg.num_layers * cfg.moe.top_k


def test_serve_matches_fixed_batch_offload_report():
    """4 scheduled requests on 4 slots == the same 4 prompts as one fixed
    batch: identical tokens, identical router trace, byte-for-byte
    identical offload report."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(4), cfg, jnp.float32)
    cfg_q, qparams, stacks = compress(cfg, params)
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, 128, (4, 6), dtype=np.int32)

    fixed = ServeEngine(cfg_q, qparams, quantized=True)
    fixed.attach_offload(stacks, policy="ours", cache_capacity=2)
    ra = fixed.generate(prompts, max_new=8)

    sched = ServeEngine(cfg_q, qparams, quantized=True)
    sched.attach_offload(stacks, policy="ours", cache_capacity=2)
    sb = sched.generate_many(list(prompts), max_new=8, num_slots=4, chunk=4)

    np.testing.assert_array_equal(
        ra.tokens, np.stack([r.tokens for r in sb.results]))
    np.testing.assert_array_equal(ra.router_trace, sb.router_trace)
    assert ra.offload_report == sb.offload_report
    assert sb.offload_report["total_bytes"] > 0
    # per-request attribution covers all demand+compensator traffic
    rep = sb.offload_report
    assert (sum(r.offload_bytes for r in sb.results)
            == rep["demand_bytes"] + rep["compensator_bytes"])


def test_router_trace_compiled_fn_cached():
    """router_trace must reuse one compiled forward per (cfg, quantized,
    kernel_impl) instead of re-jitting a fresh lambda every call."""
    from repro.serve.engine import _trace_forward
    cfg = moe_cfg()
    params = init_params(jax.random.key(6), cfg, jnp.float32)
    tokens = np.zeros((1, 8), np.int32)
    _trace_forward.cache_clear()
    a = router_trace(cfg, params, tokens)
    misses = _trace_forward.cache_info().misses
    b = router_trace(cfg, params, tokens)
    info = _trace_forward.cache_info()
    assert info.misses == misses and info.hits >= 1
    np.testing.assert_array_equal(a, b)
    assert _trace_forward(cfg, False, None)._cache_size() == 1
