"""Offline calibration / heterogeneous allocation / artifact subsystem.

Pins the PR-5 acceptance invariants:

- calibration stats agree with the first-class router trace;
- the budget allocator respects its byte budget and is monotone in it;
- at EQUAL total wire bytes, the budgeted calibrated allocation achieves
  strictly lower routing-weighted restoration error than uniform-bit
  compression (via the ``bench_accuracy.allocation_rows`` frontier the
  benchmark reports);
- artifacts round-trip bit-identically (stacks, plan, manifest), reject
  config-fingerprint mismatches and corrupt payloads;
- serving from an artifact is bit-identical (tokens, logprobs, metered
  bytes) to serving from in-memory compression of the same plan;
- heterogeneous per-expert wire bytes conserve exactly through
  ``ExpertStore`` / ``ShardedExpertStore`` metering at every shard count.
"""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calib import (SCORERS, CompressionPlan, allocate_budget,
                         collect_calibration_stats, load_compression_artifact,
                         moe_weights_by_layer, plan_wire_bytes,
                         save_compression_artifact, stacks_wire_bytes,
                         uniform_plan, weighted_restoration_error)
from repro.config import ControlConfig, ModelConfig, MoEConfig, QuantConfig
from repro.core.pipeline import compress_expert_stack
from repro.core.quantize import factor_wire_bytes, quant_wire_bytes
from repro.models import init_params
from repro.models.transformer import (apply_compressed_stacks,
                                      compress_moe_params)


def tiny_moe_cfg(e=8, k=2, layers=2, d=64, fe=64, vocab=128) -> ModelConfig:
    return ModelConfig(
        name=f"calib-test-{e}e", family="moe", num_layers=layers,
        d_model=d, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0,
        vocab_size=vocab, block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=e, top_k=k, d_expert=fe,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=8,
                                        top_n_restore=1, hqq_iters=2)))


@pytest.fixture(scope="module")
def calib_setup():
    cfg = tiny_moe_cfg()
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    stats = collect_calibration_stats(cfg, params, batches=2, batch_size=4,
                                      seq_len=32)
    weights = moe_weights_by_layer(params, cfg)
    return cfg, params, stats, weights


# ---------------------------------------------------------------------------
# stage 1: stats collection
# ---------------------------------------------------------------------------

def test_stats_agree_with_router_trace(calib_setup):
    """Counts/gate-mass come from the same routing the first-class trace
    reports: an independent host-side recount of the traced top-k ids
    must reproduce the accumulated counts exactly."""
    cfg, params, stats, _ = calib_setup
    from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
    from repro.launch.steps import make_context
    from repro.models import model as lm
    data = SyntheticLM(SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                         batch_size=4, seq_len=32, seed=0))
    ctx = make_context(cfg, "train", exact_capacity=True, collect_trace=True)
    fwd = jax.jit(lambda p, t: lm.forward(p, t, cfg, ctx).trace)
    e = cfg.moe.num_experts
    counts = np.zeros((len(stats), e))
    for bi in range(2):
        tr = np.asarray(fwd(params, jnp.asarray(data.batch(bi)["tokens"])))
        for li in range(len(stats)):
            counts[li] += np.bincount(tr[li].reshape(-1), minlength=e)
    for li, s in enumerate(stats):
        np.testing.assert_array_equal(s.counts, counts[li])
        # every routed assignment carries gate mass and an input moment
        assert s.tokens == 2 * 4 * 32
        assert (s.gate_mass[s.counts > 0] > 0).all()
        assert (s.in_moment[s.counts > 0] > 0).any(axis=1).all()
        assert s.hid_moment.shape == (e, cfg.moe.d_expert)
        imp = s.importance()
        assert imp.shape == (e,) and abs(imp.sum() - 1.0) < 1e-9
        assert (imp > 0).all()          # floored: cold experts keep a stake


# ---------------------------------------------------------------------------
# stage 2: budget allocation
# ---------------------------------------------------------------------------

def test_allocator_respects_budget_and_is_monotone(calib_setup):
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    ref = uniform_plan(weights, qcfg, bits=4, rank=8)
    errs, spents = [], []
    for frac in (0.5, 0.8, 1.1):
        budget = frac * ref.spent_bytes
        plan = allocate_budget(weights, qcfg, budget, stats=stats)
        assert plan.spent_bytes <= budget + 1e-9
        # plan bytes recompute to the same number via the shared formulas
        assert plan_wire_bytes(plan.layers, qcfg, weights) \
            == plan.spent_bytes
        errs.append(plan.predicted_err)
        spents.append(plan.spent_bytes)
    assert errs[0] >= errs[1] >= errs[2]       # more bytes, no worse
    assert spents[0] <= spents[1] <= spents[2]


def test_compressed_stacks_realize_the_plan(calib_setup):
    """The stacks' per-expert true bits/ranks and wire bytes equal the
    plan's, through the one shared byte formula."""
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    plan = allocate_budget(weights, qcfg,
                           uniform_plan(weights, qcfg, 4, 8).spent_bytes,
                           stats=stats)
    _, _, stacks = compress_moe_params(params, cfg, plan=plan, stats=stats)
    assert stacks_wire_bytes(stacks) == plan.spent_bytes
    for l, alloc in zip(stacks, plan.layers):
        for proj, stack in l.items():
            _, K, N = stack.shape
            for e in range(cfg.moe.num_experts):
                assert stack.bits_of(e) == int(alloc.bits[e])
                assert stack.ranks[e] == int(alloc.ranks[proj][e])
                want = quant_wire_bytes(stack.bits_of(e), K, N,
                                        stack.group_size) \
                    + factor_wire_bytes(stack.ranks[e], K, N,
                                        stack.factor_bits)
                assert stack.expert_wire_bytes(e, compensated=True) == want


def test_scorers_are_pluggable(calib_setup):
    """The kurtosis heuristic is one scorer among several: every scorer
    runs through the same budgeted machinery (calibrated needs stats)."""
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    budget = uniform_plan(weights, qcfg, 3, 8).spent_bytes
    for name in SCORERS:
        plan = allocate_budget(weights, qcfg, budget,
                               stats=stats if name == "calibrated" else None,
                               scorer=name)
        assert plan.spent_bytes <= budget
    with pytest.raises(ValueError):
        allocate_budget(weights, qcfg, budget, stats=None,
                        scorer="calibrated")


def test_calibrated_beats_uniform_at_equal_bytes():
    """PR acceptance: at matched total wire bytes the calibrated
    heterogeneous allocation achieves LOWER routing-weighted restoration
    error than uniform-bit compression — asserted through the exact
    frontier rows ``benchmarks/bench_accuracy.py`` reports."""
    from benchmarks.bench_accuracy import allocation_rows
    from benchmarks.common import bench_moe_cfg, heavy_tail_expert_init
    cfg = bench_moe_cfg(d_model=64, d_expert=64, vocab=128)
    params = heavy_tail_expert_init(cfg, seed=0)(jax.random.key(0))
    rows = allocation_rows(cfg, params, bits_points=(2, 3), rank=8,
                           calib_batches=2)
    for row in rows:
        assert row["calib_kb"] <= row["budget_kb"] + 1e-9, row
        assert row["calib_err"] < row["uniform_err"], row
        assert row["err_reduction_pct"] > 0, row


def test_whitened_svd_lowers_activation_weighted_error():
    """With an anisotropic input second moment, the moment-whitened
    compensator SVD beats the plain weight-space SVD in the
    activation-weighted norm at the same rank (Eckart–Young on the
    whitened residual)."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(2, 64, 48)).astype(np.float32)) * 0.1
    mom = np.geomspace(1e-2, 1e2, 64)[None, :].repeat(2, axis=0)
    qcfg = QuantConfig(enabled=True, bits=2, hqq_iters=2, factor_bits=16)
    ranks = np.array([6, 6])
    plain, _ = compress_expert_stack(w, qcfg, ranks=ranks)
    white, _ = compress_expert_stack(w, qcfg, ranks=ranks, moments=mom)
    sw = np.sqrt(mom / mom.mean(axis=1, keepdims=True))
    for e in range(2):
        def werr(stack):
            what = (np.asarray(stack.dequantize_all())
                    + np.asarray(stack.compensation_all()))[e]
            return np.linalg.norm(sw[e][:, None]
                                  * (np.asarray(w[e]) - what))
        assert werr(white) < werr(plain)


# ---------------------------------------------------------------------------
# stage 3: artifact round-trip
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_bit_identical(calib_setup, tmp_path):
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    plan = allocate_budget(weights, qcfg,
                           uniform_plan(weights, qcfg, 4, 8).spent_bytes,
                           stats=stats)
    _, _, stacks = compress_moe_params(params, cfg, plan=plan, stats=stats)
    save_compression_artifact(tmp_path / "art", cfg, stacks, plan=plan)
    loaded, plan2, meta = load_compression_artifact(tmp_path / "art", cfg)
    a = jax.tree_util.tree_leaves(stacks)
    b = jax.tree_util.tree_leaves(loaded)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # static meta (incl. heterogeneous bits/ranks) restores exactly
    for l0, l1 in zip(stacks, loaded):
        for proj in l0:
            assert l0[proj].expert_bits == l1[proj].expert_bits
            assert l0[proj].ranks == l1[proj].ranks
            assert l0[proj].shape == l1[proj].shape
    assert plan2.to_json() == plan.to_json()


def test_artifact_rejects_mismatch_and_corruption(calib_setup, tmp_path):
    cfg, params, stats, weights = calib_setup
    _, _, stacks = compress_moe_params(params, cfg)
    save_compression_artifact(tmp_path / "art", cfg, stacks)
    other = dataclasses.replace(cfg, d_model=128)
    with pytest.raises(ValueError, match="fingerprint"):
        load_compression_artifact(tmp_path / "art", other)
    # non-strict: loads, flags the mismatch for inspection tools
    _, _, meta = load_compression_artifact(tmp_path / "art", other,
                                           strict=False)
    assert "fingerprint_mismatch" in meta
    # corrupt payload -> checksum failure, never a silent wrong load.
    # The second flip targets a tensor's data bytes PAST the 4 KiB
    # prefix a sampling checksum would cover: the artifact checksum
    # hashes every byte, so deep corruption must still fail the load.
    npz = tmp_path / "art" / "artifact.npz"
    with np.load(npz) as z:
        big = max(z.files, key=lambda k: z[k].nbytes)
        assert z[big].nbytes > 8192
        needle = z[big].tobytes()[6000:6032]
    blob = bytearray(npz.read_bytes())
    deep = blob.find(needle)
    assert deep > 0
    for offset in (len(blob) // 2, deep + 16):
        blob = bytearray(npz.read_bytes())
        blob[offset] ^= 0xFF
        npz.write_bytes(bytes(blob))
        with pytest.raises(Exception):
            load_compression_artifact(tmp_path / "art", cfg)
        save_compression_artifact(tmp_path / "art", cfg, stacks)  # restore


def test_artifact_roundtrips_bf16_factors(calib_setup, tmp_path):
    """factor_bits=16 stores compensators as bfloat16 — a dtype numpy
    only knows via ml_dtypes.  The codec must round-trip it (uint16 view
    + logical dtype in the leaf spec), not pickle-and-fail at load."""
    cfg, params, _, _ = calib_setup
    qcfg = dataclasses.replace(cfg.moe.quant, factor_bits=16)
    _, _, stacks = compress_moe_params(params, cfg, qcfg=qcfg)
    assert stacks[0]["w1"].u.dtype == jnp.bfloat16
    save_compression_artifact(tmp_path / "art16", cfg, stacks)
    loaded, _, _ = load_compression_artifact(tmp_path / "art16", cfg)
    for x, y in zip(jax.tree_util.tree_leaves(stacks),
                    jax.tree_util.tree_leaves(loaded)):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8))


def test_serve_from_artifact_bit_identical(calib_setup, tmp_path):
    """launch/serve.py --artifact semantics: booting the saved stacks
    produces the same tokens, logprobs, and metered wire bytes as
    in-memory compression of the same plan — no recompression happened
    and none was needed."""
    from repro.serve import ServeEngine
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    plan = allocate_budget(weights, qcfg,
                           uniform_plan(weights, qcfg, 3, 8).spent_bytes,
                           stats=stats)
    qp, cfg_q, stacks = compress_moe_params(params, cfg, plan=plan,
                                            stats=stats)
    save_compression_artifact(tmp_path / "art", cfg, stacks, plan=plan)
    loaded, _, _ = load_compression_artifact(tmp_path / "art", cfg)
    qp2, cfg_q2 = apply_compressed_stacks(params, cfg, loaded)
    assert cfg_q2 == cfg_q

    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    r = []
    for p_, s_ in ((qp, stacks), (qp2, loaded)):
        eng = ServeEngine(cfg_q, p_, quantized=True)
        eng.attach_offload(s_, policy="ours", cache_capacity=8)
        r.append(eng.generate(prompts, max_new=6))
    np.testing.assert_array_equal(r[0].tokens, r[1].tokens)
    np.testing.assert_array_equal(r[0].logprobs, r[1].logprobs)
    assert r[0].offload_report["total_bytes"] \
        == r[1].offload_report["total_bytes"] > 0


# ---------------------------------------------------------------------------
# heterogeneous wire bytes through the offload meter
# ---------------------------------------------------------------------------

def test_hetero_bytes_conserve_across_shard_counts(calib_setup):
    """Per-expert heterogeneous bytes flow through ``ExpertStore`` and
    ``ShardedExpertStore`` metering identically: the same routing
    sequence meters the same totals at ep in {1, 2, 4, 8}, per-shard
    bytes sum exactly, and distinct per-expert costs are really
    exercised."""
    from repro.offload.store import ExpertStore, ShardedExpertStore
    cfg, params, stats, weights = calib_setup
    qcfg = cfg.moe.quant
    plan = allocate_budget(weights, qcfg,
                           uniform_plan(weights, qcfg, 4, 8).spent_bytes,
                           stats=stats)
    _, _, stacks = compress_moe_params(params, cfg, plan=plan, stats=stats)
    layer = stacks[0]
    e = cfg.moe.num_experts
    base = ExpertStore(layer, cache_capacity=e)
    per_expert = [base.expert_bytes(i, "ours") for i in range(e)]
    assert len(set(per_expert)) > 1      # heterogeneity is real
    rng = np.random.default_rng(0)
    topks = rng.integers(0, e, size=(64, 2))
    def run(store):
        for tk in topks:
            store.access_token(tk, top_n=1, policy="ours", rank_cap=None)
        return store.total_bytes
    total1 = run(base)
    for ep in (2, 4, 8):
        sh = ShardedExpertStore(layer, ep=ep, cache_capacity=e)
        total = run(sh)
        assert total == total1
        assert int(sh.shard_totals.sum()) == total
    # the metered unique-fetch bytes match the stacks' own accounting
    uniq = np.unique(topks)
    want = sum(layer[p].expert_wire_bytes(int(i), False)
               for p in layer for i in uniq)
    assert base.cache.stats.bytes_moved == want


def test_controller_ladder_respects_true_ranks():
    """from_stacks tops the rank ladder at the layer's max TRUE rank:
    pad-rank alignment slack contributes no identity rungs, and the
    inactive static plan caps at the true rank."""
    from repro.serve.controller import BandwidthController, static_plan
    stacks = {"w1": SimpleNamespace(ranks=(4, 2, 0), pad_rank=16),
              "w2": SimpleNamespace(ranks=(2, 1, 0), pad_rank=16)}
    c = BandwidthController.from_stacks([stacks], top_k=2,
                                        ccfg=ControlConfig(),
                                        static_top_n=1)
    assert c.pad_ranks == (4,)
    plan = c.plan()
    assert int(plan.rank_cap[0]) == 4        # not the padded 16
    # every active rung's cap stays within the true-rank ceiling
    for lvl in range(c.max_level + 1):
        assert int(c.plan_at(lvl).rank_cap[0]) <= 4
