"""Shared benchmark utilities: tiny trained MoE + compression variants."""
from __future__ import annotations

import dataclasses
import functools
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig, TrainConfig
from repro.core import compress_ffn_weights
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import ExecContext, forward, init_params
from repro.train import train

CACHE_DIR = Path("experiments/bench_cache")


def bench_moe_cfg(num_experts=8, top_k=2, d_model=128, d_expert=256,
                  layers=2, vocab=512) -> ModelConfig:
    """Mixtral-shaped miniature (8 experts top-2) for quality benchmarks."""
    return ModelConfig(
        name=f"bench-moe-{num_experts}e", family="moe", num_layers=layers,
        d_model=d_model, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=0, vocab_size=vocab, block_pattern=("global",),
        max_position=2048,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k,
                      d_expert=d_expert,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=32, top_n_restore=1)))


def heavy_tail_expert_init(cfg: ModelConfig, seed: int = 0):
    """init_fn that draws each expert's weights from a Student-t with a
    per-expert tail index (df 2.2 … 30, std-normalized).

    Large-scale-trained MoE experts are heavy-tailed with heterogeneous
    kurtosis (paper Fig 4; KurTail [1]); a briefly-trained toy model stays
    Gaussian, so we graft that spectrum at init — tails persist through
    short training and give the kurtosis-guided allocation something real
    to discriminate.  (Documented in EXPERIMENTS.md §Methodology.)
    """
    def init_fn(key):
        params = init_params(key, cfg, jnp.float32)
        rng = np.random.default_rng(seed)
        e = cfg.moe.num_experts
        dfs = np.geomspace(2.2, 30.0, e)
        rng.shuffle(dfs)

        def retail(w):  # (…, E, K, N)
            w = np.asarray(w)
            out = w.copy()
            lead = w.shape[:-3]
            for idx in np.ndindex(*lead) if lead else [()]:
                for ei in range(e):
                    df = dfs[ei]
                    t = rng.standard_t(df, w.shape[-2:]).astype(np.float32)
                    t /= np.sqrt(df / (df - 2.0))          # unit std
                    out[idx + (ei,)] = t * w[idx + (ei,)].std()
            return jnp.asarray(out)

        for seg in params["segments"]:
            for p in seg:
                if "moe" in p:
                    for k in ("w1", "w2", "w3"):
                        p["moe"][k] = retail(p["moe"][k])
        return params

    return init_fn


@functools.lru_cache(maxsize=4)
def trained_moe(num_experts=8, top_k=2, steps=150, seed=0
                ) -> Tuple[ModelConfig, Dict]:
    """Train (or load cached) a tiny MoE on the synthetic Zipf-Markov LM,
    with heavy-tailed per-expert weight spectra (see heavy_tail_expert_init)."""
    cfg = bench_moe_cfg(num_experts=num_experts, top_k=top_k)
    cache = CACHE_DIR / f"moe_{num_experts}e{top_k}k_{steps}s_{seed}"
    from repro.checkpoint import CheckpointManager
    from repro.launch.steps import TrainState
    from repro.optim.adamw import adamw_init
    tcfg = TrainConfig(total_steps=steps, lr=2e-3, warmup_steps=20,
                       checkpoint_every=10 ** 9, loss_chunk=0, seed=seed)
    if (cache / ("step_%08d.json" % steps)).exists():
        mgr = CheckpointManager(cache)
        params = init_params(jax.random.key(seed), cfg, jnp.float32)
        state, _ = mgr.restore(TrainState(params, adamw_init(params)))
        return cfg, state.params
    res = train(cfg, tcfg, checkpoint_dir=str(cache), log_every=50,
                batch_shape=(8, 128),
                init_fn=heavy_tail_expert_init(cfg, seed))
    return cfg, res.state.params


def compress_model(cfg: ModelConfig, params, qcfg: QuantConfig
                   ) -> Tuple[ModelConfig, Dict, Dict]:
    """Offline-compress every MoE layer's experts.

    Scanned segments are unrolled first (per-layer kurtosis/rank allocation
    makes the stacks heterogeneous); returns (cfg', params', reports)."""
    from repro.models.transformer import unstack_params
    cfg2 = dataclasses.replace(
        cfg, force_unroll_plan=True,
        moe=dataclasses.replace(cfg.moe, quant=qcfg) if cfg.moe else None)
    params = unstack_params(params, cfg)
    new_segs = []
    reports = {}
    for si, seg in enumerate(params["segments"]):
        pos = []
        for pi, p in enumerate(seg):
            p = dict(p)
            if "moe" in p:
                mp = dict(p["moe"])
                stacks, rep = compress_ffn_weights(
                    mp["w1"], mp["w2"], mp["w3"], qcfg)
                reports[f"layer{si}_{pi}"] = rep
                mp["stacks"] = stacks
                for k in ("w1", "w2", "w3"):
                    mp.pop(k)
                p["moe"] = mp
            pos.append(p)
        new_segs.append(tuple(pos))
    out = dict(params)
    out["segments"] = tuple(new_segs)
    return cfg2, out, reports


def eval_nll(cfg: ModelConfig, params, *, quantized: bool,
             batches: int = 4, seed: int = 0,
             step_offset: int = 50_000) -> float:
    """Held-out mean NLL on the synthetic stream.

    Same language seed as training (the Markov structure IS the language);
    held-out-ness comes from a disjoint, deterministic step range."""
    data = SyntheticLM(SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                         batch_size=8, seq_len=128,
                                         seed=seed))
    ctx = ExecContext(mode="train", quantized=quantized,
                      exact_capacity=True)

    @jax.jit
    def nll(params, tokens):
        out = forward(params, tokens, cfg, ctx)
        logits = out.logits[:, :-1].astype(jnp.float32)
        tgt = tokens[:, 1:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        sel = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - sel)

    vals = [float(nll(params,
                      jnp.asarray(data.batch(step_offset + i)["tokens"])))
            for i in range(batches)]
    return float(np.mean(vals))


def timed(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us
