"""Event-driven throughput simulator for offloaded MoE decoding (Fig 7).

Replays real router traces (from the JAX model) through a two-resource
pipeline — transfer link and compute device — with double buffering:
layer l+1's expert fetch overlaps layer l's compute, exactly the
Mixtral-Offloading execution model.  Policies:

  fp16           Mixtral-Offloading: fetch fp16 experts on demand
  quant          HOBBIT-style low-bit uniform fetch
  ours           BEAM-LRC: low-bit fetch + top-n compensators (paper)
  ours_adaptive  BEAM-LRC under the runtime bandwidth-budget controller
                 (serve/controller.py): per-layer (top_n, rank_cap)
                 adapted online to a bytes/token budget
  *_ndp          MoNDE-style: cold experts execute on the NDP in low
                 precision, only top-n compensated experts run on the
                 fast device

Reported tokens/s is per request stream (batch 1 decode, the paper's
setting), with expert compute times from the hardware profile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..config import ControlConfig
from .bandwidth import GPU_NDP, GPU_ONLY, HardwareProfile
from .store import ExpertCache


@dataclasses.dataclass
class LayerSpecSim:
    """Static per-layer description of the MoE being served."""
    d_model: int
    d_expert: int
    num_experts: int
    top_k: int
    bytes_fp16: int          # per expert, all projections
    bytes_quant: int         # per expert, packed low-bit + scales
    comp_bytes: Sequence[int]  # per expert compensator bytes (true ranks)
    # per-expert true compensator ranks — required by the adaptive policy
    # (rank_cap scales comp_bytes by min(rank, cap) / rank)
    ranks: Optional[Sequence[int]] = None


@dataclasses.dataclass
class SimResult:
    tokens_per_s: float
    transfer_bytes_per_token: float
    transfer_time_frac: float
    cache_hit_rate: float
    compute_time_frac: float
    # adaptive-policy telemetry (0.0 under the static policies)
    mean_top_n: float = 0.0
    mean_rank_cap: float = 0.0
    # bytes/token over the second half of the trace — the converged
    # operating point once the controller's transient has settled
    # (equals the plain average under static policies)
    tail_bytes_per_token: float = 0.0


def expert_flops(spec: LayerSpecSim) -> float:
    return 2.0 * 3 * spec.d_model * spec.d_expert


def _capped_comp_bytes(spec: LayerSpecSim, e: int, cap: Optional[int]) -> int:
    cb = int(spec.comp_bytes[e])
    if cap is None or spec.ranks is None:
        return cb
    r = int(spec.ranks[e])
    return int(cb * min(r, int(cap)) / r) if r > 0 else 0


def simulate_decode(trace: np.ndarray, spec: LayerSpecSim,
                    profile: HardwareProfile, policy: str, *,
                    top_n: int = 1, cache_capacity: int = 2,
                    num_layers: int = 32, prefetch: bool = False,
                    control: Optional[ControlConfig] = None,
                    ctrl_interval: int = 4) -> SimResult:
    """trace: (tokens, layers, top_k) routed expert ids.

    Two-resource pipeline (link, device).  On-demand mode (default,
    Mixtral-Offloading semantics): a layer's fetch is issued only after the
    previous layer computed (the router decides what to fetch).  With
    ``prefetch=True`` the fetch is issued as soon as the link is free AND
    the layer-ahead prediction exists — the prediction for layer ``l``
    becomes available when layer ``l``'s router last ran (the previous
    token's pass), matching the real transfer engine's
    ``LayerAheadPrefetcher``: a first-touch layer has no prediction yet
    and falls back to on-demand issue.

    ``policy='ours_adaptive'`` (or ``'ours_adaptive_ndp'``) runs the
    bandwidth-budget controller in the loop: every ``ctrl_interval``
    tokens the link bytes moved since the last update feed
    ``BandwidthController.update`` and the per-layer (top_n, rank_cap)
    plan of the *next* tokens follows ``control``'s budget.  The
    controller sees only byte counters, so the simulation stays
    deterministic for a given trace + budget.
    """
    ndp = policy.endswith("_ndp")
    base_policy = policy.replace("_ndp", "")
    adaptive = base_policy == "ours_adaptive"
    if adaptive:
        base_policy = "ours"
        if control is None:
            raise ValueError("policy 'ours_adaptive' needs a ControlConfig")
        if spec.ranks is None:
            raise ValueError("policy 'ours_adaptive' needs LayerSpecSim."
                             "ranks (per-expert true compensator ranks)")
        from ..serve.controller import BandwidthController
        pad = max(int(r) for r in spec.ranks)
        ctrl = BandwidthController([pad] * trace.shape[1], spec.top_k,
                                   control, static_top_n=top_n)
        plan = ctrl.plan()
    else:
        ctrl = None
        plan = None
    caches = [ExpertCache(cache_capacity) for _ in range(num_layers)]
    # per-cache resident compensator rank caps, ExpertStore._comp_resident
    # semantics (e -> cap, None = full rank, absent = none resident):
    # factors ride the cache with their expert, a later cap raise moves
    # only the delta rows — keeps sim bytes identical to the store meter
    comp_res: List[Dict[int, Optional[int]]] = [{} for _ in range(num_layers)]
    # prediction availability per trace layer: the time layer l's router
    # last ran (None until first touch — no prediction to act on yet)
    pred_ready: List[Optional[float]] = [None] * trace.shape[1]
    t_link = 0.0      # link busy-until
    t_dev = 0.0       # device busy-until
    busy_link = 0.0
    busy_dev = 0.0
    total_bytes = 0
    half_bytes = 0
    eflops = expert_flops(spec)
    ctrl_bytes_mark = 0
    plan_sum = np.zeros((2,), np.float64)
    plan_obs = 0

    tokens = trace.shape[0]
    for tok in range(tokens):
        for layer in range(trace.shape[1]):
            cache = caches[layer % num_layers]
            resident = comp_res[layer % num_layers]
            experts = trace[tok, layer]
            if plan is not None:
                layer_top_n = int(plan.top_n[layer])
                layer_cap = int(plan.rank_cap[layer])
                plan_sum += (layer_top_n, layer_cap)
                plan_obs += 1
            else:
                layer_top_n, layer_cap = top_n, None
            move = 0
            dev_flops = 0.0
            dev_bytes = 0.0
            ndp_time = 0.0
            for rank, e in enumerate(experts):
                e = int(e)
                restored = base_policy == "ours" and rank < layer_top_n
                if ndp and not restored:
                    # cold expert executes near-data in low precision
                    ndp_time += profile.ndp_compute_time(
                        eflops, spec.bytes_quant)
                    continue
                nbytes = (spec.bytes_fp16 if base_policy == "fp16"
                          else spec.bytes_quant)
                if not cache.access(e, nbytes):
                    move += nbytes
                if cache.last_evicted is not None:
                    resident.pop(cache.last_evicted, None)
                if restored:
                    # compensators ride the cache with their expert
                    # (ExpertStore.access_token semantics): fetch only the
                    # rank rows not already resident
                    have = resident.get(e, -1)        # -1 = absent
                    need = _capped_comp_bytes(spec, e, layer_cap)
                    if have is not None:
                        held = (0 if have < 0
                                else _capped_comp_bytes(spec, e, have))
                        if need > held:
                            move += need - held
                        if (have < 0 or layer_cap is None
                                or layer_cap > have):
                            resident[e] = layer_cap
                    nbytes += need
                dev_flops += eflops
                dev_bytes += nbytes
            # fetch issue time: on-demand waits for the router (= prev
            # layer's compute); prefetch for the link AND the layer-ahead
            # prediction (causal: layer l's router must have run once)
            if prefetch and pred_ready[layer] is not None:
                issue = max(t_link, pred_ready[layer])
            else:
                issue = max(t_link, t_dev)
            tt = profile.transfer_time(move) if move else 0.0
            t_ready = issue + tt
            t_link = t_ready
            busy_link += tt
            # device: compute is max(flop-time, weight-streaming from HBM)
            comp = max(profile.compute_time(dev_flops),
                       profile.hbm_time(dev_bytes))
            start = max(t_ready, t_dev)
            # layer l's router runs as its compute begins: from here on a
            # prefetch of the NEXT token's layer-l prediction may issue
            pred_ready[layer] = start
            t_dev = start + comp + ndp_time
            busy_dev += comp + ndp_time
            total_bytes += move
        if tok + 1 == tokens // 2:
            half_bytes = total_bytes
        if ctrl is not None and (tok + 1) % ctrl_interval == 0:
            plan = ctrl.update(total_bytes - ctrl_bytes_mark, ctrl_interval)
            ctrl_bytes_mark = total_bytes
    wall = max(t_link, t_dev)
    hit = float(np.mean([c.stats.hit_rate for c in caches]))
    mean_tn, mean_rc = ((plan_sum / plan_obs).tolist() if plan_obs
                        else (0.0, 0.0))
    return SimResult(
        tokens_per_s=tokens / wall if wall > 0 else float("inf"),
        transfer_bytes_per_token=total_bytes / tokens,
        transfer_time_frac=busy_link / wall if wall else 0.0,
        cache_hit_rate=hit,
        compute_time_frac=busy_dev / wall if wall else 0.0,
        mean_top_n=float(mean_tn), mean_rank_cap=float(mean_rc),
        tail_bytes_per_token=((total_bytes - half_bytes)
                              / max(tokens - tokens // 2, 1)))


def make_router_trace(probs_fn, tokens: int, layers: int, top_k: int,
                      seed: int = 0, skew: float = 0.0,
                      num_experts: int = 8) -> np.ndarray:
    """Synthetic fallback trace with controllable router skew (benchmarks
    prefer real traces exported from the JAX model)."""
    rng = np.random.default_rng(seed)
    out = np.zeros((tokens, layers, top_k), np.int64)
    base = rng.dirichlet(np.ones(num_experts) * (1.0 - skew + 0.05),
                         size=layers)
    for t in range(tokens):
        for l in range(layers):
            p = base[l] + rng.dirichlet(np.ones(num_experts)) * 0.3
            p /= p.sum()
            out[t, l] = np.argsort(-p)[:top_k]
    return out
