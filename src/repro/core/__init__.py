"""BEAM-LRC core: the paper's contribution as composable JAX modules."""
from .quantize import (PLANES, PACK_BLOCK, QuantizedTensor, dequantize,
                       factor_wire_bytes, pack_bits, packed_nbytes,
                       quant_error, quant_wire_bytes, quantize,
                       quantize_codes, quantize_with_params, unpack_bits)
from .hqq import hqq_params, hqq_quantize, shrink_lp
from .kurtosis import allocate_ranks, kurtosis, uniform_ranks
from .compensator import (Compensator, build_compensator, compensated_weight,
                          compensation_quality)
from .pipeline import (CompressedExpertStack, compress_expert_stack,
                       compress_ffn_weights)
from .restoration import (compensated_expert_ffn, restoration_wire_bytes,
                          topn_mask, topn_mask_from_scores)
