"""Pallas TPU kernels for the paper's compute hot spots.

- quant_matmul:          x @ dequant(bit-plane packed Wq)
- lowrank_comp_matmul:   fused dequant matmul + router-guided rank-r epilogue

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd dispatch
wrapper in ``ops.py`` (auto-selects pallas on TPU, ref on CPU; tests run
``pallas_interpret``).
"""
from . import ops, ref
from .ops import (compensated_matmul_stack, default_impl, lowrank_comp_matmul,
                  quant_matmul)
