"""Layer-ahead expert prefetcher (related-work systems [5,19,33,42]).

While layer l computes, predict layer l+1's experts and issue their
fetches.  Prediction uses the previous token's routing at l+1 (decode-time
temporal locality) — the cheap predictor HOBBIT-class systems use; accuracy
and the wasted-fetch ratio are metered so benchmarks can quantify the
prediction-miss penalty the paper's related-work section describes.

The prediction set is capped at ``top_k`` experts per active request
stream (ranked by how many streams routed to them last step): ``top_k``
is the router's per-token fetch width, so the prefetcher never issues
more speculative traffic per stream than the demand path would.
``ExpertStore.prefetch`` inserts the predictions into the device LRU and
meters their bytes — correct predictions become cache *hits* on the
demand access, mispredictions are metered as wasted prefetch bytes
(``offload/store.py::replay_decode_trace``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PrefetchStats:
    issued: int = 0
    useful: int = 0
    wasted: int = 0

    @property
    def accuracy(self) -> float:
        return self.useful / self.issued if self.issued else 0.0


class LayerAheadPrefetcher:
    """Predicts layer l+1 experts = previous token's experts at l+1."""

    def __init__(self, num_layers: int, top_k: int):
        self.top_k = int(top_k)
        self.prev_token: List[Optional[np.ndarray]] = [None] * num_layers
        self.stats = PrefetchStats()

    def predict(self, layer: int) -> Optional[np.ndarray]:
        return self.prev_token[layer]

    def observe(self, layer: int, experts: np.ndarray):
        """Score the pending prediction against this step's experts and
        remember them for the next step.  ``experts`` may be any shape
        (batched decode passes the whole step's (rows, k) ids); entries
        < 0 (masked scheduler slots) are ignored; the stored prediction
        keeps at most ``top_k`` experts per observed row, most-frequent
        first."""
        a = np.asarray(experts)
        rows = a.reshape(-1, a.shape[-1]) if a.ndim > 1 else a.reshape(1, -1)
        rows = rows[(rows >= 0).any(axis=1)]
        flat = rows.reshape(-1)
        flat = flat[flat >= 0]
        if flat.size == 0:
            # Fully-masked step: the pending prediction went unconsumed.
            # Expire it rather than keep it alive — a later step would
            # meter the stale warm as a fresh (and likely wasted)
            # prefetch for routing that is now a full step old.
            self.prev_token[layer] = None
            return
        uniq, counts = np.unique(flat, return_counts=True)
        order = np.argsort(-counts, kind="stable")
        cap = self.top_k * max(len(rows), 1)
        experts = np.sort(uniq[order[:cap]])
        pred = self.prev_token[layer]
        if pred is not None:
            hit = len(np.intersect1d(pred, experts))
            self.stats.issued += len(pred)
            self.stats.useful += hit
            self.stats.wasted += len(pred) - hit
        self.prev_token[layer] = experts.copy()


class LookaheadPrefetcher:
    """Router-speculative lookahead prefetcher (serve/speculative.py).

    The speculative verify pass computes router decisions for all k+1
    round positions in one batched forward, so while layer l streams the
    routing of every not-yet-verified token at layer l is already known:
    predictions are *exact* routing, not a heuristic.  What is
    speculative is the tokens themselves — warms issued for positions
    the rejection sampler later discards are the attributable cost of
    speculation, metered separately (``bytes_wasted`` = draft overhead
    bytes) from the layer-ahead heuristic's misprediction waste.

    Per round, ``begin_round`` installs the verify trace
    (steps, layers, rows, k); ``predict(step, layer)`` returns the
    deduplicated expert set that position touches; ``score`` splits the
    issued prediction into useful (used by a scheduler-accepted
    position) and wasted (rejected suffix / dead slot) and accumulates
    the byte attribution.
    """

    def __init__(self, num_layers: int, top_k: int):
        self.num_layers = int(num_layers)
        self.top_k = int(top_k)
        self.stats = PrefetchStats()
        self.bytes_issued = 0          # all lookahead prefetch bytes fetched
        self.bytes_wasted = 0          # subset issued for rejected positions
        self._trace: Optional[np.ndarray] = None

    def begin_round(self, trace: np.ndarray):
        """Install one verify round's router trace, shaped
        (steps, layers, rows, k) with masked entries < 0."""
        t = np.asarray(trace)
        assert t.ndim == 4 and t.shape[1] == self.num_layers, t.shape
        self._trace = t

    def predict(self, step: int, layer: int) -> Optional[np.ndarray]:
        if self._trace is None:
            return None
        flat = self._trace[step, layer].reshape(-1)
        flat = np.unique(flat[flat >= 0])
        return flat if flat.size else None

    def score(self, pred: np.ndarray, accepted_rows: np.ndarray,
              fetched: Dict[int, int]) -> int:
        """Score one (step, layer) prediction.  ``accepted_rows`` holds
        the routing of the scheduler-accepted rows at that position
        (empty when the position was rejected wholesale); ``fetched``
        maps expert -> bytes actually moved by the warm.  Returns the
        wasted-byte subtotal so the caller can charge the store's
        wasted-prefetch meter."""
        used = np.unique(accepted_rows[accepted_rows >= 0]) \
            if accepted_rows.size else np.empty((0,), np.int64)
        hit = len(np.intersect1d(pred, used))
        self.stats.issued += len(pred)
        self.stats.useful += hit
        self.stats.wasted += len(pred) - hit
        wasted_b = sum(b for e, b in fetched.items() if e not in set(used.tolist()))
        self.bytes_issued += sum(fetched.values())
        self.bytes_wasted += wasted_b
        return wasted_b
