"""Expert store + device cache for offloaded serving (paper §2.1, §4.3).

``ExpertStore`` keeps compressed experts in *host* memory (numpy) and
fetches them on demand; ``ExpertCache`` is the device-resident LRU that
Mixtral-Offloading/HOBBIT-style systems maintain.  Every fetch is metered
in bytes so benchmarks can report exact PCIe/host-link traffic for
fp16 / uniform-quant / BEAM-LRC policies.

Metering semantics (fidelity-critical for the paper's wire-byte claims):

- compensator factors *ride the device cache* with the expert they
  compensate: they are fetched once when a top-n expert first needs them,
  stay resident while the expert does, and are refetched only after the
  expert is evicted — not re-charged on every token; under the bandwidth
  controller's per-layer rank caps only the capped factor rows move, and
  a later cap *raise* fetches just the missing rows (the delta);
- prefetched experts are inserted into the LRU ahead of the access (so a
  correct prediction becomes a *hit*) and their traffic is metered as
  ``prefetch_bytes``; bytes fetched for predictions the step never used
  are additionally reported as ``wasted_prefetch_bytes``;
- expert ids < 0 mark inactive scheduler slots and are skipped entirely.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import CompressedExpertStack
from ..core.quantize import factor_wire_bytes


@dataclasses.dataclass
class FetchStats:
    bytes_moved: int = 0
    fetches: int = 0
    hits: int = 0
    misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ExpertCache:
    """Per-layer LRU over expert ids with byte-metered misses.

    ``last_evicted`` holds the expert id dropped by the most recent
    ``access``/``insert`` (or None) — the store uses it to evict that
    expert's cache-resident compensator factors along with the weights.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._lru: "collections.OrderedDict[int, int]" = collections.OrderedDict()
        self.stats = FetchStats()
        self.last_evicted: Optional[int] = None

    def __contains__(self, expert: int) -> bool:
        return expert in self._lru

    def _insert(self, expert: int, nbytes: int):
        self._lru[expert] = nbytes
        self.last_evicted = None
        if len(self._lru) > self.capacity:
            self.last_evicted, _ = self._lru.popitem(last=False)

    def access(self, expert: int, nbytes: int) -> bool:
        """True on hit; on miss, meters ``nbytes`` and inserts."""
        self.last_evicted = None
        if expert in self._lru:
            self._lru.move_to_end(expert)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        self.stats.fetches += 1
        self.stats.bytes_moved += nbytes
        self._insert(expert, nbytes)
        return False

    def insert(self, expert: int, nbytes: int) -> bool:
        """Prefetch-path insert: warms the LRU without touching hit/miss
        stats (the demand access decides those) and without metering into
        ``stats.bytes_moved`` (the caller meters prefetch bytes).  Returns
        True if the expert was actually fetched (i.e. was not resident)."""
        self.last_evicted = None
        if expert in self._lru:
            self._lru.move_to_end(expert)
            return False
        self._insert(expert, nbytes)
        return True


class ExpertStore:
    """Host-side store of one MoE layer's compressed projections.

    ``fetch_policy``:
      'fp16'   — move full-precision experts (Mixtral-Offloading baseline)
      'quant'  — uniform low-bit, no compensators (HQQ/GPTQ baseline)
      'ours'   — low-bit + compensators for the top-n experts (BEAM-LRC)
    """

    def __init__(self, stacks: Dict[str, CompressedExpertStack],
                 cache_capacity: int = 4):
        self.stacks = stacks
        self.num_experts = next(iter(stacks.values())).scale.shape[0]
        self.cache = ExpertCache(cache_capacity)
        self.comp_bytes_moved = 0
        self.prefetch_bytes = 0
        self.wasted_prefetch_bytes = 0
        # async transfer engine (offload/staging.py).  When attached, the
        # meter drives real copies: every metering event calls back into
        # the engine, and the engine acknowledges each copy it puts on
        # the link via ``note_copy`` — the observed side of the
        # metered-bytes == observed-copies oracle.
        self._engine = None
        self.observed_copies = 0
        self.observed_copy_bytes = 0
        # expert -> rank cap its device-resident compensator factors were
        # fetched at (None = uncapped / full true rank); factors ride the
        # LRU with their expert (evicted together, refetched on the next
        # top-n access after eviction).  When the bandwidth controller
        # *raises* a layer's rank cap, the next access fetches only the
        # missing factor rows (the delta), not the whole factor again.
        self._comp_resident: Dict[int, Optional[int]] = {}

    def expert_bytes(self, e: int, policy: str) -> int:
        if policy == "fp16":
            return sum(s.fp16_wire_bytes for s in self.stacks.values())
        return sum(s.expert_wire_bytes(e, compensated=False)
                   for s in self.stacks.values())

    def compensator_bytes(self, e: int, rank_cap: Optional[int] = None
                          ) -> int:
        """Factor wire bytes for expert ``e`` at ``rank_cap`` (None = the
        true allocated rank; the cap slices the rank-padded factors)."""
        total = 0
        for s in self.stacks.values():
            r = s.ranks[e] if rank_cap is None else min(s.ranks[e],
                                                        int(rank_cap))
            total += factor_wire_bytes(r, s.shape[1], s.shape[2],
                                       s.factor_bits)
        return total

    def _drop_evicted(self):
        if self.cache.last_evicted is not None:
            self._comp_resident.pop(self.cache.last_evicted, None)

    # -- transfer-engine plumbing ------------------------------------------
    def attach_engine(self, hook):
        """Attach a transfer-engine hook (``on_demand`` / ``on_factors`` /
        ``on_prefetch``); pass None to detach."""
        self._engine = hook

    def note_copy(self, nbytes: int):
        """Transfer-engine acknowledgement that ``nbytes`` were put on the
        link for a metering event of this store (counted at copy issue)."""
        self.observed_copies += 1
        self.observed_copy_bytes += int(nbytes)

    def absorb_external_copy(self, e: int, nbytes: int,
                             comp_rank: Optional[int] = None,
                             comp_bytes: int = 0) -> int:
        """Meter a copy the engine performed that no demand/compensator
        event claimed (an optimistic stage the accepted trace never
        touched): insert the expert so residency matches the container,
        charge the traffic as prefetch, and acknowledge the copy.
        Returns the bytes metered (the caller attributes them to
        ``wasted_prefetch_bytes``)."""
        e = int(e)
        moved = 0
        if nbytes:
            if self.cache.insert(e, int(nbytes)):
                self._drop_evicted()
                moved += int(nbytes)
        if comp_bytes:
            have = self._comp_resident.get(e, -1)
            if have is not None:
                self._comp_resident[e] = comp_rank
                moved += int(comp_bytes)
        if moved:
            self.prefetch_bytes += moved
            self.note_copy(moved)
        return moved

    def access_token(self, topk: np.ndarray, top_n: int, policy: str,
                     rank_cap: Optional[int] = None) -> int:
        """Meter one token's expert fetches; returns bytes moved.

        Entries < 0 (masked / inactive scheduler slots) are skipped.
        ``rank_cap`` caps the compensator rank fetched for restored
        experts (the controller's per-layer plan; None = full rank)."""
        before = self.total_bytes
        for rank, e in enumerate(topk):
            e = int(e)
            if e < 0:
                continue
            hit = self.cache.access(e, self.expert_bytes(e, policy))
            self._drop_evicted()
            if not hit and self._engine is not None:
                self._engine.on_demand(self, e, self.expert_bytes(e, policy))
            if policy == "ours" and rank < top_n:
                # compensators ride the cache with their expert: fetch
                # only what is not already resident (a raised cap fetches
                # the missing rank rows only)
                have = self._comp_resident.get(e, -1)     # -1 = absent
                if have is None:
                    continue                              # full rank resident
                need = self.compensator_bytes(e, rank_cap)
                held = 0 if have < 0 else self.compensator_bytes(e, have)
                if need > held:
                    self.comp_bytes_moved += need - held
                    if self._engine is not None:
                        self._engine.on_factors(self, e, have, rank_cap,
                                                need - held)
                if have < 0 or rank_cap is None or rank_cap > have:
                    self._comp_resident[e] = rank_cap
        return self.total_bytes - before

    def prefetch(self, experts: Iterable[int], policy: str
                 ) -> Dict[int, int]:
        """Warm the LRU with predicted experts ahead of the demand access.

        Fetched bytes land in ``prefetch_bytes`` (they are real wire
        traffic); returns {expert: bytes} for the experts actually fetched
        so the caller can meter the wasted share after scoring."""
        fetched: Dict[int, int] = {}
        for e in experts:
            e = int(e)
            if e < 0:
                continue
            nb = self.expert_bytes(e, policy)
            if e in self.cache:
                self.cache.insert(e, nb)          # refresh LRU position
                continue
            if self._engine is not None and not self._engine.on_prefetch(
                    self, e, nb):
                # staging ring full: the copy cannot move, so the store
                # must neither meter it nor warm the LRU with it
                continue
            self.cache.insert(e, nb)
            self._drop_evicted()
            self.prefetch_bytes += nb
            fetched[e] = nb
        return fetched

    @property
    def total_bytes(self) -> int:
        return (self.cache.stats.bytes_moved + self.comp_bytes_moved
                + self.prefetch_bytes)

    @property
    def shard_totals(self) -> np.ndarray:
        """(1,) per-shard wire bytes — the single-shard degenerate form of
        ``ShardedExpertStore.shard_totals`` so reduction code is uniform."""
        return np.asarray([self.total_bytes], np.int64)


# ---------------------------------------------------------------------------
# expert-parallel sharded store
# ---------------------------------------------------------------------------

class ShardedExpertStore:
    """EP partition of one MoE layer's store: ``ep`` per-shard
    ``ExpertStore``s, shard ``s`` owning the contiguous expert slice
    ``[s * E/ep, (s+1) * E/ep)`` — the same partition ``shard_map`` gives
    the device-side expert weights (``distributed/moe_parallel.py``).

    Each shard meters only its *resident* experts' wire bytes over its
    own device LRU and host link; a token's top-k fans out across the
    owning shards with the global rank positions preserved, so the
    router-guided ``top_n`` compensation decision is identical to the
    single-store path.  Aggregate properties reduce the per-shard
    counters for reports and the bandwidth controller; ``shard_totals``
    exposes the unreduced per-link bytes for the controller's
    ``per_shard`` budget scope and ``ServeStats``.

    Byte conservation: residency state (device LRU + resident compensator
    rank caps) is per-expert within a shard, and every expert belongs to
    exactly one shard at any shard count — so as long as no shard evicts
    (per-shard ``cache_capacity`` >= its E/ep residents), total demand +
    compensator bytes for the same routing trace are EXACTLY equal across
    shard counts (pinned by tests).  Under eviction pressure, totals may
    legitimately differ: partitioning the LRU changes cache locality,
    on real hardware as here.
    """

    def __init__(self, stacks: Dict[str, CompressedExpertStack], ep: int,
                 cache_capacity: int = 4):
        num_experts = next(iter(stacks.values())).scale.shape[0]
        if ep < 1 or num_experts % ep:
            raise ValueError(f"{num_experts} experts do not partition over "
                             f"ep={ep} shards")
        self.stacks = stacks
        self.ep = ep
        self.num_experts = num_experts
        self.experts_per_shard = num_experts // ep
        self.shards = [ExpertStore(stacks, cache_capacity=cache_capacity)
                       for _ in range(ep)]
        self.wasted_prefetch_bytes = 0

    def _owner(self, e: int) -> int:
        return int(e) // self.experts_per_shard

    def expert_bytes(self, e: int, policy: str) -> int:
        return self.shards[0].expert_bytes(e, policy)

    def compensator_bytes(self, e: int, rank_cap: Optional[int] = None
                          ) -> int:
        return self.shards[0].compensator_bytes(e, rank_cap)

    def access_token(self, topk: np.ndarray, top_n: int, policy: str,
                     rank_cap: Optional[int] = None) -> int:
        """Meter one token's fetches across the owning shards.

        Foreign experts are masked to -1 *in place of their rank
        position* before each shard's access, so ``rank < top_n``
        compensates exactly the assignments the single-store path would.
        """
        topk = np.asarray(topk)
        total = 0
        for s, shard in enumerate(self.shards):
            lo = s * self.experts_per_shard
            local = np.where((topk >= lo)
                             & (topk < lo + self.experts_per_shard),
                             topk, -1)
            if (local >= 0).any():
                total += shard.access_token(local, top_n=top_n,
                                            policy=policy, rank_cap=rank_cap)
        return total

    def prefetch(self, experts: Iterable[int], policy: str
                 ) -> Dict[int, int]:
        """Route predicted experts to their owning shard's prefetcher."""
        fetched: Dict[int, int] = {}
        for e in experts:
            e = int(e)
            if e < 0:
                continue
            fetched.update(self.shards[self._owner(e)].prefetch([e], policy))
        return fetched

    # -- transfer-engine plumbing ------------------------------------------
    def attach_engine(self, hook):
        """Attach one transfer-engine hook to every shard.  Expert
        ownership is disjoint across shards, so the shared per-layer
        engine state (containers, ring, ledger) sees each expert's
        events from exactly one shard."""
        for s in self.shards:
            s.attach_engine(hook)

    def absorb_external_copy(self, e: int, nbytes: int,
                             comp_rank: Optional[int] = None,
                             comp_bytes: int = 0) -> int:
        return self.shards[self._owner(e)].absorb_external_copy(
            e, nbytes, comp_rank=comp_rank, comp_bytes=comp_bytes)

    @property
    def observed_copies(self) -> int:
        return sum(s.observed_copies for s in self.shards)

    @property
    def observed_copy_bytes(self) -> int:
        return sum(s.observed_copy_bytes for s in self.shards)

    # -- aggregate views (same API surface as ExpertStore) -----------------
    @property
    def comp_bytes_moved(self) -> int:
        return sum(s.comp_bytes_moved for s in self.shards)

    @property
    def prefetch_bytes(self) -> int:
        return sum(s.prefetch_bytes for s in self.shards)

    @property
    def total_bytes(self) -> int:
        return sum(s.total_bytes for s in self.shards)

    @property
    def shard_totals(self) -> np.ndarray:
        """(ep,) wire bytes that crossed each shard's link."""
        return np.asarray([s.total_bytes for s in self.shards], np.int64)

    @property
    def cache(self):
        """Aggregated cache-stats facade (``snapshot_offload`` reads
        ``store.cache.stats``); hit/miss/fetch counts sum exactly because
        every expert access lands on exactly one shard."""
        agg = FetchStats(
            bytes_moved=sum(s.cache.stats.bytes_moved for s in self.shards),
            fetches=sum(s.cache.stats.fetches for s in self.shards),
            hits=sum(s.cache.stats.hits for s in self.shards),
            misses=sum(s.cache.stats.misses for s in self.shards))
        return _CacheView(agg)


@dataclasses.dataclass
class _CacheView:
    stats: FetchStats


def make_expert_stores(stacks_by_layer: List[Dict], *, ep: int = 1,
                       cache_capacity: int = 4) -> List:
    """Per-layer stores for serving: plain ``ExpertStore``s at ``ep=1``
    (or when the expert count does not partition — the engine's GSPMD
    fallback path), ``ShardedExpertStore``s otherwise."""
    stores = []
    for stacks in stacks_by_layer:
        e = next(iter(stacks.values())).scale.shape[0]
        if ep > 1 and e % ep == 0:
            stores.append(ShardedExpertStore(stacks, ep,
                                             cache_capacity=cache_capacity))
        else:
            stores.append(ExpertStore(stacks, cache_capacity=cache_capacity))
    return stores


# ---------------------------------------------------------------------------
# trace replay + reporting
# ---------------------------------------------------------------------------

def snapshot_offload(stores: List[ExpertStore], prefetcher=None) -> Dict:
    """Cumulative store/prefetcher counters, for delta-based reports.

    ``per_shard`` is the element-wise sum of every store's
    ``shard_totals`` — the per-link wire bytes under expert-parallel
    sharding, a length-1 vector for plain single-shard stores."""
    return {
        "demand": sum(s.cache.stats.bytes_moved for s in stores),
        "comp": sum(s.comp_bytes_moved for s in stores),
        "prefetch": sum(s.prefetch_bytes for s in stores),
        "wasted": sum(s.wasted_prefetch_bytes for s in stores),
        "total": sum(s.total_bytes for s in stores),
        "hits": sum(s.cache.stats.hits for s in stores),
        "misses": sum(s.cache.stats.misses for s in stores),
        "per_shard": sum(np.asarray(s.shard_totals, np.int64)
                         for s in stores),
        # observed transfer-engine copies (0 until streaming is attached);
        # the oracle pins observed == total per store, so these columns
        # let reports cross-check metered traffic against real copies
        "observed": sum(s.observed_copy_bytes for s in stores),
        "copies": sum(s.observed_copies for s in stores),
        "pf_issued": prefetcher.stats.issued if prefetcher is not None else 0,
        "pf_useful": prefetcher.stats.useful if prefetcher is not None else 0,
    }


def offload_report(stores: List[ExpertStore], prefetcher, snap: Dict,
                   tokens: int, policy: str) -> Dict:
    """Report dict covering the traffic since ``snap`` (snapshot_offload)."""
    now = snapshot_offload(stores, prefetcher)
    d = {k: now[k] - snap[k] for k in now}
    issued = d["pf_issued"]
    per_shard = np.asarray(d["per_shard"], np.int64).reshape(-1)
    return {
        "policy": policy,
        "tokens": tokens,
        "total_bytes": int(d["total"]),
        "bytes_per_token": d["total"] / max(tokens, 1),
        "demand_bytes": int(d["demand"]),
        "compensator_bytes": int(d["comp"]),
        "prefetch_bytes": int(d["prefetch"]),
        "wasted_prefetch_bytes": int(d["wasted"]),
        "hit_rate": d["hits"] / max(d["hits"] + d["misses"], 1),
        "prefetch_accuracy": (d["pf_useful"] / max(issued, 1)
                              if prefetcher is not None else None),
        # expert-parallel reduction: per-link traffic + the hottest link
        # (what the controller's per_shard budget scope targets)
        "ep": int(per_shard.shape[0]),
        "per_shard_bytes": [int(b) for b in per_shard],
        "max_shard_bytes_per_token": (int(per_shard.max())
                                      / max(tokens, 1)),
        "observed_copy_bytes": int(d["observed"]),
        "observed_copies": int(d["copies"]),
    }


def _per_layer(val, layers: int, default):
    """Broadcast a scalar / per-layer sequence plan knob to (layers,)."""
    if val is None:
        return [default] * layers
    arr = np.asarray(val)
    if arr.ndim == 0:
        return [arr.item()] * layers
    if arr.shape[0] != layers:
        raise ValueError(f"per-layer plan has {arr.shape[0]} entries for "
                         f"{layers} MoE layers")
    return [a.item() for a in arr]


def replay_decode_trace(stores: List[ExpertStore], trace: np.ndarray, *,
                        policy: str = "ours", top_n=1,
                        rank_caps=None,
                        prefetcher=None) -> Tuple[int, np.ndarray]:
    """Replay a (steps, moe_layers, B, k) decode trace into the stores.

    Batch rows whose expert ids are < 0 are *inactive scheduler slots*:
    they are skipped by the prefetcher and the stores.  ``top_n`` and
    ``rank_caps`` may be scalars or per-layer (moe_layers,) sequences —
    the bandwidth controller's plan; ``rank_caps=None`` meters full-rank
    compensators (the static pre-controller behaviour).  Returns
    ``(tokens, slot_bytes)`` — the number of active (step, slot) tokens
    metered and the demand+compensator bytes attributed per batch slot
    (prefetch traffic is shared and not slot-attributable).
    """
    trace = np.asarray(trace)
    steps, layers, b, _ = trace.shape
    if layers != len(stores):
        raise ValueError(f"trace has {layers} MoE layers but "
                         f"{len(stores)} stores attached")
    top_ns = _per_layer(top_n, layers, 1)
    caps = _per_layer(rank_caps, layers, None)
    slot_bytes = np.zeros((b,), np.int64)
    tokens = 0
    for t in range(steps):
        active = trace[t, 0, :, 0] >= 0               # (B,) slot mask
        if not active.any():
            continue
        tokens += int(active.sum())
        for l in range(layers):
            experts = trace[t, l]                     # (B, k)
            live = experts[active]
            if prefetcher is not None:
                # while layer l-1 computes, fetch the predicted experts of
                # layer l so correct predictions turn into cache hits
                pred = prefetcher.predict(l)
                fetched = (stores[l].prefetch(pred, policy)
                           if pred is not None else {})
                prefetcher.observe(l, live)
                if fetched:
                    used = set(int(e) for e in live.reshape(-1))
                    stores[l].wasted_prefetch_bytes += sum(
                        nb for e, nb in fetched.items() if e not in used)
            for bi in np.nonzero(active)[0]:
                slot_bytes[bi] += stores[l].access_token(
                    experts[bi], top_n=top_ns[l], policy=policy,
                    rank_cap=caps[l])
    return tokens, slot_bytes


def replay_spec_round(stores: List[ExpertStore], trace: np.ndarray,
                      accepted: np.ndarray, *,
                      policy: str = "ours", top_n=1, rank_caps=None,
                      lookahead=None) -> Tuple[int, np.ndarray, int]:
    """Replay one speculative verify round into the stores.

    ``trace``: (round_steps, moe_layers, B, k) — the verify pass's FULL
    router trace, covering accepted *and* rejected positions of live
    slots (inactive scheduler slots masked to -1).  ``accepted``:
    (round_steps, B) bool — the scheduler-accepted positions; only those
    are demand-metered, matching the non-speculative convention that
    masked compute never reaches the wire-byte meter.  The
    ``lookahead`` prefetcher (``LookaheadPrefetcher``) warms the stores
    for EVERY live position — warms for positions that end up rejected
    are the attributable cost of speculation, charged to the stores'
    wasted-prefetch meter and returned as draft overhead bytes.

    Every byte still moves through ``ExpertStore.prefetch`` /
    ``access_token``, so the streaming transfer engine observes a real
    copy for every metered byte and the PR 8 oracle
    (``total_bytes == observed_copy_bytes``) holds with speculation on.

    Returns ``(tokens, slot_bytes, draft_overhead_bytes)``.
    """
    trace = np.asarray(trace)
    accepted = np.asarray(accepted, bool)
    steps, layers, b, _ = trace.shape
    if layers != len(stores):
        raise ValueError(f"trace has {layers} MoE layers but "
                         f"{len(stores)} stores attached")
    if accepted.shape != (steps, b):
        raise ValueError(f"accepted mask {accepted.shape} != {(steps, b)}")
    top_ns = _per_layer(top_n, layers, 1)
    caps = _per_layer(rank_caps, layers, None)
    if lookahead is not None:
        lookahead.begin_round(trace)
    slot_bytes = np.zeros((b,), np.int64)
    tokens = 0
    overhead = 0
    for t in range(steps):
        live = trace[t, 0, :, 0] >= 0                 # (B,) slot mask
        acc = accepted[t] & live
        tokens += int(acc.sum())
        if not live.any():
            continue
        for l in range(layers):
            experts = trace[t, l]                     # (B, k)
            if lookahead is not None:
                pred = lookahead.predict(t, l)
                fetched = (stores[l].prefetch(pred, policy)
                           if pred is not None else {})
                if pred is not None:
                    wb = lookahead.score(pred, experts[acc], fetched)
                    stores[l].wasted_prefetch_bytes += wb
                    overhead += wb
            for bi in np.nonzero(acc)[0]:
                slot_bytes[bi] += stores[l].access_token(
                    experts[bi], top_n=top_ns[l], policy=policy,
                    rank_cap=caps[l])
    return tokens, slot_bytes, overhead


def meter_decode_trace(stores: List[ExpertStore], trace: np.ndarray, *,
                       policy: str = "ours", top_n=1,
                       rank_caps=None, prefetcher=None) -> Dict:
    """Replay a live decode trace through per-layer stores.

    ``trace``: (steps, moe_layers, B, k) routed expert ids, exactly the
    ``GenerationResult.router_trace`` the serve engine's jitted decode
    loop emits — so the wire bytes / hit rates below are measured from
    real serving decisions, not the synthetic simulator.  Batch rows with
    expert id -1 are inactive scheduler slots and are skipped.

    The stores keep their cumulative lifetime stats (and cache state warm
    across calls); the returned report covers THIS replay only, so
    repeated ``generate`` calls don't double-count earlier traffic.

    Returns a report dict: bytes/token (demand + compensator + prefetch),
    per-category bytes, cache hit rate, prefetch accuracy.
    """
    snap = snapshot_offload(stores, prefetcher)
    tokens, _ = replay_decode_trace(stores, trace, policy=policy,
                                    top_n=top_n, rank_caps=rank_caps,
                                    prefetcher=prefetcher)
    return offload_report(stores, prefetcher, snap, tokens, policy)
