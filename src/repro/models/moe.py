"""Mixture-of-Experts layer with router-guided low-rank restoration.

Three execution paths share one routing/dispatch core:

- ``moe_apply`` (single-shard): capacity dispatch via scatter/gather —
  used by smoke tests, examples, and *inside* the shard_map paths.
- ``moe_apply_ep_a2a`` (train/prefill): tokens sharded over (pod, data[,
  model]); experts sharded over ``model``; two ``lax.all_to_all``s move
  dispatched tokens to their expert shard and back.
- ``moe_apply_ep_replicated`` (decode): tokens replicated over ``model``;
  each shard computes only its resident experts and a psum combines.

The paper's technique rides the same dispatch: when expert weights are
``CompressedExpertStack``s, each (expert, slot) carries a 0/1 top-n mask
and the expert FFN applies the low-rank compensator only where masked.
Execution of the expert FFN itself (dense einsum / reference quantized /
fused Pallas kernel) is owned by ``models.expert_backend`` and selected
via the ``kernels.ops`` impl policy.  Every path also returns its
``RoutingInfo`` so callers (serve engine, offload metering) get the
router trace as a first-class output instead of hooking ``route``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import axis_size
from ..config import MoEConfig
from .expert_backend import (ExpertBackend, expert_ffn_dense,
                             select_backend)


class RoutingInfo(NamedTuple):
    gates: jax.Array        # (T, k) normalized top-k gate values
    topk_idx: jax.Array     # (T, k) expert ids, descending score
    probs: jax.Array        # (T, E) full softmax (aux losses)
    logits: jax.Array       # (T, E)


def route(x2: jax.Array, w_router: jax.Array, mcfg: MoEConfig) -> RoutingInfo:
    """x2: (T, d) -> routing for top-k experts (softmax-then-topk)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, topi = jax.lax.top_k(probs, mcfg.top_k)
    if mcfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return RoutingInfo(gates, topi, probs, logits)


def aux_losses(info: RoutingInfo, mcfg: MoEConfig) -> Dict[str, jax.Array]:
    """Switch-style load-balance + router z-loss (mean over local tokens)."""
    t, e = info.probs.shape
    top1 = info.topk_idx[:, 0]
    frac = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(info.probs, axis=0)
    lb = e * jnp.sum(frac * mean_prob)
    z = jnp.mean(jax.scipy.special.logsumexp(info.logits, axis=-1) ** 2)
    return {"load_balance": lb * mcfg.router_aux_weight,
            "router_z": z * mcfg.router_z_weight}


# ---------------------------------------------------------------------------
# capacity dispatch (index-based: O(T k d), no (T, E, C) einsum blowup)
# ---------------------------------------------------------------------------

class Dispatch(NamedTuple):
    e_idx: jax.Array        # (T*k,) target expert per assignment
    slot: jax.Array         # (T*k,) capacity slot (>=C means dropped)
    t_idx: jax.Array        # (T*k,) source token per assignment
    gates: jax.Array        # (T*k,)
    comp: jax.Array         # (T*k,) 1.0 if assignment rank < top_n_restore
    capacity: int


def make_dispatch(info: RoutingInfo, num_experts: int, capacity: int,
                  top_n) -> Dispatch:
    """``top_n`` may be a static int or a traced scalar (the bandwidth
    controller's per-layer plan): the comp mask is a compare either way,
    so a runtime plan change never retriggers compilation."""
    t, k = info.topk_idx.shape
    e_idx = info.topk_idx.reshape(-1)
    # slot within expert: exclusive running count of prior assignments
    oh = jax.nn.one_hot(e_idx, num_experts, dtype=jnp.int32)     # (T*k, E)
    slot = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(t * k), e_idx]
    t_idx = jnp.repeat(jnp.arange(t), k)
    rank = jnp.tile(jnp.arange(k), t)
    comp = (rank < top_n).astype(jnp.float32)
    return Dispatch(e_idx, slot, t_idx, info.gates.reshape(-1), comp,
                    capacity)


def dispatch_tokens(x2: jax.Array, d: Dispatch, num_experts: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Scatter (T, dm) tokens into (E, C, dm) expert buffers + comp mask."""
    dm = x2.shape[-1]
    xe = jnp.zeros((num_experts, d.capacity, dm), x2.dtype)
    xe = xe.at[d.e_idx, d.slot].set(x2[d.t_idx], mode="drop")
    me = jnp.zeros((num_experts, d.capacity), jnp.float32)
    me = me.at[d.e_idx, d.slot].set(d.comp, mode="drop")
    return xe, me


def dispatch_gates(d: Dispatch, num_experts: int) -> jax.Array:
    """Scatter router gates into the (E, C) slot layout.

    Companion buffer for backends with ``fuses_gates``: the kernel
    multiplies each expert-slot output by its gate (the gate-weighted
    combine), and ``combine_tokens(pre_weighted=True)`` then just
    gathers and scatter-adds.  Dropped assignments (slot >= C) are
    out of bounds for the scatter and vanish via ``mode='drop'``."""
    ge = jnp.zeros((num_experts, d.capacity), jnp.float32)
    return ge.at[d.e_idx, d.slot].set(d.gates, mode="drop")


def combine_tokens(ye: jax.Array, d: Dispatch, num_tokens: int, *,
                   pre_weighted: bool = False) -> jax.Array:
    """Gather (E, C, dm) expert outputs back to (T, dm), gate-weighted.

    ``pre_weighted=True`` means the backend already folded the gates in
    (``ExpertBackend.fuses_gates`` + ``dispatch_gates``): skip the gate
    multiply here — the ``mode='fill'`` gather already zeroes dropped
    assignments (slot >= C reads out of bounds)."""
    ya = ye.at[d.e_idx, d.slot].get(mode="fill", fill_value=0.0)  # (T*k, dm)
    if not pre_weighted:
        # dropped assignments (slot >= C) must contribute zero
        keep = (d.slot < d.capacity).astype(ya.dtype)
        ya = ya * (d.gates * keep)[:, None].astype(ya.dtype)
    y = jnp.zeros((num_tokens, ye.shape[-1]), ya.dtype)
    return y.at[d.t_idx].add(ya)


def _capacity(tokens: int, mcfg: MoEConfig, exact: bool) -> int:
    if exact:
        return tokens
    c = int(math.ceil(tokens * mcfg.top_k * mcfg.capacity_factor
                      / mcfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


# ---------------------------------------------------------------------------
# single-shard path
# ---------------------------------------------------------------------------

def _plan_knobs(mcfg: MoEConfig, quantized: bool, plan):
    """Resolve (top_n, rank_cap) for one MoE layer invocation.

    ``plan`` is this layer's (2,) int32 row of the bandwidth controller's
    per-layer plan array — traced values, so runtime plan changes reuse
    the compiled fn.  None (controller absent) keeps the static
    ``QuantConfig.top_n_restore`` / uncapped-rank behaviour bit-exactly.
    """
    if not quantized:
        return 0, None
    if plan is None:
        return mcfg.quant.top_n_restore, None
    return plan[0], plan[1]


def moe_apply(x2: jax.Array, params: Dict, mcfg: MoEConfig, *,
              act: str = "silu", quantized: bool = False,
              exact_capacity: bool = False,
              impl: Optional[str] = None,
              backend: Optional[ExpertBackend] = None,
              plan: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array], RoutingInfo]:
    """x2: (T, d) -> (T, d), aux losses, routing info.  Runs on one shard."""
    t = x2.shape[0]
    backend = backend or select_backend(params, quantized, impl)
    info = route(x2, params["router"], mcfg)
    cap = _capacity(t, mcfg, exact_capacity)
    top_n, rank_cap = _plan_knobs(mcfg, quantized, plan)
    disp = make_dispatch(info, mcfg.num_experts, cap, top_n)
    xe, me = dispatch_tokens(x2, disp, mcfg.num_experts)
    fuse = getattr(backend, "fuses_gates", False)
    ge = dispatch_gates(disp, mcfg.num_experts) if fuse else None
    ye = backend(xe, params, me, act, rank_cap=rank_cap, gates=ge)
    y = combine_tokens(ye, disp, t, pre_weighted=fuse)
    return y.astype(x2.dtype), aux_losses(info, mcfg), info


# ---------------------------------------------------------------------------
# expert-parallel paths (run INSIDE shard_map; 'model' = EP axis)
# ---------------------------------------------------------------------------

def moe_apply_ep_a2a(x2: jax.Array, params: Dict, mcfg: MoEConfig, *,
                     act: str = "silu", quantized: bool = False,
                     axis: str = "model", impl: Optional[str] = None,
                     backend: Optional[ExpertBackend] = None,
                     plan: Optional[jax.Array] = None,
                     exact_capacity: bool = False
                     ) -> Tuple[jax.Array, Dict[str, jax.Array], RoutingInfo]:
    """Tokens local, experts sharded on ``axis``: dispatch via all_to_all.

    params['w*'] / stack leaves carry the LOCAL expert slice (E_local, ...).
    ``exact_capacity`` dispatches at capacity = local tokens (drop-free),
    so a sharded serve matches the single-device engine's drop behaviour
    token for token.
    """
    t = x2.shape[0]
    ep = axis_size(axis)
    e_total = mcfg.num_experts
    backend = backend or select_backend(params, quantized, impl)
    info = route(x2, params["router"], mcfg)
    cap = _capacity(t, mcfg, exact_capacity)
    top_n, rank_cap = _plan_knobs(mcfg, quantized, plan)
    disp = make_dispatch(info, e_total, cap, top_n)
    xe, me = dispatch_tokens(x2, disp, e_total)          # (E, C, d) local
    fuse = getattr(backend, "fuses_gates", False)
    ge = dispatch_gates(disp, e_total) if fuse else None
    # -> (E_local, C * ep, d): every shard receives its experts' slots
    xe = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=1, tiled=True)
    me = jax.lax.all_to_all(me, axis, split_axis=0, concat_axis=1, tiled=True)
    if ge is not None:
        ge = jax.lax.all_to_all(ge, axis, split_axis=0, concat_axis=1,
                                tiled=True)
    ye = backend(xe, params, me, act, rank_cap=rank_cap, gates=ge)
    ye = jax.lax.all_to_all(ye, axis, split_axis=1, concat_axis=0, tiled=True)
    y = combine_tokens(ye, disp, t, pre_weighted=fuse)
    aux = jax.tree.map(lambda v: jax.lax.pmean(v, axis),
                       aux_losses(info, mcfg))
    return y.astype(x2.dtype), aux, info


def moe_apply_ep_replicated(x2: jax.Array, params: Dict, mcfg: MoEConfig, *,
                            act: str = "silu", quantized: bool = False,
                            axis: str = "model", impl: Optional[str] = None,
                            backend: Optional[ExpertBackend] = None,
                            plan: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, Dict[str, jax.Array],
                                       RoutingInfo]:
    """Decode path: tokens replicated over ``axis``; each shard runs its
    resident experts at exact capacity and a psum combines partials."""
    t = x2.shape[0]
    ep = axis_size(axis)
    m = jax.lax.axis_index(axis)
    e_total = mcfg.num_experts
    e_local = e_total // ep
    backend = backend or select_backend(params, quantized, impl)
    info = route(x2, params["router"], mcfg)
    # map global expert ids into the local slice; foreign ids -> OOB (drop)
    topi_local = info.topk_idx - m * e_local
    oob = (topi_local < 0) | (topi_local >= e_local)
    topi_local = jnp.where(oob, e_local, topi_local)     # OOB sentinel
    local_info = RoutingInfo(jnp.where(oob, 0.0, info.gates), topi_local,
                             info.probs, info.logits)
    top_n, rank_cap = _plan_knobs(mcfg, quantized, plan)
    disp = make_dispatch(local_info, e_local + 1, t, top_n)
    xe, me = dispatch_tokens(x2, disp, e_local + 1)
    xe, me = xe[:e_local], me[:e_local]
    fuse = getattr(backend, "fuses_gates", False)
    ge = dispatch_gates(disp, e_local + 1)[:e_local] if fuse else None
    ye = backend(xe, params, me, act, rank_cap=rank_cap, gates=ge)
    ye = jnp.concatenate([ye, jnp.zeros_like(ye[:1])], axis=0)
    y = combine_tokens(ye, disp, t, pre_weighted=fuse)
    y = jax.lax.psum(y, axis)
    return y.astype(x2.dtype), aux_losses(info, mcfg), info
