"""Unified expert-execution backend: one place that owns the expert FFN.

All three MoE paths (``moe_apply``, ``moe_apply_ep_a2a``,
``moe_apply_ep_replicated``) dispatch the (E, C, d) expert-stacked buffers
through a single :func:`select_backend` decision instead of inlining the
dense/quantized branch.  Backends:

  ``dense``   reference einsum over full-precision (E, d, f) stacks
  ``ref``     quantized + router-guided compensation via the batched einsum
              oracle (``core.restoration.compensated_expert_ffn``)
  ``pallas``  ONE fused Pallas kernel per projection over the whole expert
              stack (``kernels.ops.fused_expert_matmul``): bitplane unpack
              + dequant at each expert's true width, the rank-capped
              compensator GEMM, and — on the down projection — the
              gate-weighted combine, all inside the kernel
              (``fuses_gates``); also runs under the Pallas interpreter
              on CPU (``pallas_interpret``)

Selection follows the kernel dispatch policy in ``kernels.ops``
(``REPRO_KERNEL_IMPL`` env / ``impl`` argument: auto | pallas |
pallas_interpret | ref), so the Pallas kernels are reachable from the
model rather than dead code behind the benchmarks.

Expert-parallel serving runs these same backends INSIDE the shard_map
regions of ``distributed/moe_parallel.py``: the ``params`` dict then
carries each shard's LOCAL expert slice — ``(E/ep, ...)`` weight /
stack leaves (with ``CompressedExpertStack.shape`` still naming the
global E, which is static metadata; kernels index only runtime leaves)
— and ``xe`` the shard's dispatched ``(E_local, C, d)`` buffers.  The
engine's ``kernel_impl`` threads through ``ExecContext`` into the
region, so one dispatch policy selects the execution path on every
shard, sharded or not.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..core.pipeline import CompressedExpertStack
from ..core.restoration import compensated_expert_ffn
from ..kernels import ops
from .layers import activation


def expert_ffn_dense(xe: jax.Array, w1, w3, w2, act: str) -> jax.Array:
    """xe: (E, C, d); w1/w3: (E, d, f); w2: (E, f, d)."""
    f = activation(act)
    h = jnp.einsum("ecd,edf->ecf", xe, w1)
    h = f(h) * jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def expert_stacks(params: Dict) -> Dict[str, CompressedExpertStack]:
    """The layer's live compressed stacks — the single read point the
    quantized backends go through.

    Streamed-container contract (``serve`` ``attach_streaming`` /
    ``offload/staging.py``): under async expert streaming this dict holds
    the mutable device CONTAINERS — booted from the low-bit fallback,
    with true expert payloads scattered in between scan chunks.  The
    stream engine only ever replaces entry VALUES with pytree/shape/
    dtype-identical stacks (meta fields — the jit signature — never
    change), so backends must (a) re-read the dict each call rather than
    caching stacks across calls, and (b) never assume a stack leaf aliases
    the offline-compressed original.  Both quantized backends below
    already satisfy this by construction; new backends should fetch
    stacks through this helper to inherit the contract.
    """
    return params["stacks"]


class ExpertBackend:
    """Executes the expert FFN over dispatched (E, C, d) buffers.

    ``me`` is the (E, C) 0/1 router-guided compensation mask and
    ``rank_cap`` the traced per-layer compensator rank ceiling from the
    bandwidth controller's plan (None = full padded rank); both are
    ignored by the dense backend.

    ``gates`` is the optional (E, C) slot-scattered router gate buffer.
    Backends that set ``fuses_gates = True`` weight their output by it
    in-kernel (the gate-weighted combine), and the MoE combine step then
    skips its own gate multiply (``combine_tokens(pre_weighted=True)``).
    Backends that leave it False ignore ``gates`` and the combine
    applies them as before.
    """

    name = "base"
    fuses_gates = False

    def __call__(self, xe: jax.Array, params: Dict, me: jax.Array,
                 act: str, rank_cap: Optional[jax.Array] = None,
                 gates: Optional[jax.Array] = None) -> jax.Array:
        raise NotImplementedError


class DenseBackend(ExpertBackend):
    """Full-precision einsum experts (training / uncompressed serving)."""

    name = "dense"

    def __call__(self, xe, params, me, act, rank_cap=None, gates=None):
        return expert_ffn_dense(xe, params["w1"], params["w3"], params["w2"],
                                act)


class RefQuantBackend(ExpertBackend):
    """Quantized experts with masked compensation — batched einsum oracle."""

    name = "ref"

    def __call__(self, xe, params, me, act, rank_cap=None, gates=None):
        stacks = expert_stacks(params)
        return compensated_expert_ffn(
            xe, stacks["w1"], stacks.get("w3"), stacks["w2"], me,
            act=activation(act), dtype=xe.dtype, rank_cap=rank_cap)


class PallasQuantBackend(ExpertBackend):
    """One fused Pallas kernel invocation per (layer, projection).

    ``impl`` is the *resolved* kernel implementation ('pallas' or
    'pallas_interpret'); each projection runs
    ``kernels.ops.fused_expert_matmul`` over the whole expert stack —
    bitplane unpack + HQQ dequant at each expert's true per-expert
    width, the rank-capped low-rank compensator GEMM, and (on the down
    projection, when the caller threads ``gates``) the gate-weighted
    combine — so no dequantized weight and no per-expert Python loop is
    ever materialized, and the traced (top_n, rank_cap) plan row enters
    as data.
    """

    name = "pallas"
    fuses_gates = True

    def __init__(self, impl: str = "pallas"):
        self.impl = impl

    def __call__(self, xe, params, me, act, rank_cap=None, gates=None):
        stacks = expert_stacks(params)
        f = activation(act)
        h1 = ops.fused_expert_matmul(xe, stacks["w1"], me,
                                     impl=self.impl,
                                     out_dtype=jnp.float32,
                                     rank_cap=rank_cap)
        if "w3" in stacks:
            h3 = ops.fused_expert_matmul(xe, stacks["w3"], me,
                                         impl=self.impl,
                                         out_dtype=jnp.float32,
                                         rank_cap=rank_cap)
            h = f(h1) * h3
        else:
            h = f(h1)
        ye = ops.fused_expert_matmul(h.astype(xe.dtype), stacks["w2"],
                                     me, gates=gates, impl=self.impl,
                                     out_dtype=jnp.float32,
                                     rank_cap=rank_cap)
        return ye.astype(xe.dtype)


def select_backend(params: Dict, quantized: bool,
                   impl: Optional[str] = None) -> ExpertBackend:
    """Pick the expert backend for one MoE layer invocation.

    Dense weights (or ``quantized=False``) always run the einsum path;
    compressed stacks dispatch on the resolved kernel impl policy
    (``REPRO_KERNEL_IMPL`` / ``impl``): 'ref' uses the batched einsum
    oracle, 'pallas'/'pallas_interpret' the fused kernel.  Called per
    shard inside the expert-parallel shard_map paths with the local
    param slice — the decision depends only on tree structure and the
    impl policy, so every shard selects the same backend.
    """
    if not quantized or "stacks" not in params:
        return DenseBackend()
    resolved = ops.resolve_impl(impl)
    if resolved == "ref":
        return RefQuantBackend()
    return PallasQuantBackend(resolved)
